"""Hypothesis property tests on the serving layer's fairness and
admission-control invariants (ISSUE #6 satellite).

Requires the optional ``hypothesis`` dependency (requirements-dev.txt);
collection skips cleanly on bare environments. Each property's body is a
plain checker function so the same assertions can be driven without
hypothesis (the fuzz corpus reuses none of these — they are scheduler-
level, not parity-level).

Properties:
  * **no starvation** — under drain-limited WFQ every backlogged tenant
    is served at least once every ``ceil(2*W/(w*D)) + 2`` windows (W =
    total weight, w = the tenant's weight, D = drain limit), and a
    drain-limited flush always drains exactly ``min(D, pending)`` leaves
    (work conservation: ``ceil(total/D)`` flushes to empty).
  * **weights are monotone** — on a fixed replayed trace with a
    deterministic service-time model, doubling a tenant's SLO weight
    never increases that tenant's p99 submit->redeem latency.
  * **rejections are inert** — submissions refused by admission control
    (``QueueFull``) never mutate RMW table state: the flushed result
    equals the NumPy oracle applied to the admitted prefix only, and the
    caller's array is untouched.
"""
import math

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import Engine  # noqa: E402
from repro.core.scheduler import QueueFull, Scheduler  # noqa: E402
from repro.serve import (AccessService,  # noqa: E402
                         FixedWindowController, TrafficConfig,
                         generate_trace, replay_trace)

_small = dict(max_examples=25, deadline=None)
_ENGINE = Engine(tile_size=64)          # shared: jit caches hit across runs
_T = np.arange(64, dtype=np.float32)


def _service_model(depth, report):
    return 200.0 + 8.0 * depth


# ---------------------------------------------------------------------------
# no starvation / work conservation
# ---------------------------------------------------------------------------

def check_no_starvation(seed: int) -> None:
    rng = np.random.default_rng(seed)
    n_ten = int(rng.integers(2, 6))
    weights = [float(rng.choice([0.25, 0.5, 1.0, 2.0, 4.0]))
               for _ in range(n_ten)]
    counts = [int(rng.integers(1, 13)) for _ in range(n_ten)]
    drain = int(rng.integers(1, 7))
    sched = Scheduler(engine=_ENGINE)
    for i, w in enumerate(weights):
        sched.configure_tenant(f"t{i}", weight=w)
    order = [i for i, c in enumerate(counts) for _ in range(c)]
    rng.shuffle(order)
    for i in order:
        sched.submit_gather(_T, np.arange(4), tenant=f"t{i}")

    served_at: dict = {}
    wi = 0
    while sched.pending:
        before = sched.pending
        rep = sched.flush(drain_limit=drain, inflight_ok=True)
        # work conservation: a drain-limited window is always full
        assert len(rep.order) == min(drain, before)
        for t, _ in rep.order:
            served_at.setdefault(t, []).append(wi)
        wi += 1
    assert wi == math.ceil(sum(counts) / drain)

    total_w = sum(weights)
    for i, w in enumerate(weights):
        sv = served_at[f"t{i}"]
        assert len(sv) == counts[i]          # nothing lost, nothing dup'd
        gaps = [sv[0] + 1] + [b - a for a, b in zip(sv, sv[1:])]
        bound = math.ceil(2.0 * total_w / (w * drain)) + 2
        assert max(gaps) <= bound, (
            f"tenant t{i} (w={w}) starved: served at windows {sv}, "
            f"worst gap {max(gaps)} > bound {bound} "
            f"(weights={weights}, counts={counts}, D={drain})")


class TestNoStarvation:
    @given(st.integers(0, 10_000))
    @settings(**_small)
    def test_every_backlogged_tenant_is_served_within_bound(self, seed):
        check_no_starvation(seed)


# ---------------------------------------------------------------------------
# weight monotonicity
# ---------------------------------------------------------------------------

def hot_tenant_p99(seed: int, threshold: int, weight: float) -> float:
    trace = generate_trace(TrafficConfig(
        seed=seed, n_events=250, n_tenants=50, p_program=0.0, p_tick=0.0))
    counts: dict = {}
    for e in trace.events:
        counts[e.tenant] = counts.get(e.tenant, 0) + 1
    hot = max(counts, key=counts.get)
    svc = AccessService(tile_size=256, auto_flush=0,
                        controller=FixedWindowController(
                            threshold, max_wait_us=2000.0,
                            drain_cap=max(2, threshold // 2)))
    svc.connect(hot, weight=weight)
    replay_trace(trace, svc, service_time=_service_model)
    return svc.telemetry.tenant_stats(hot).p99_us


def check_weight_monotone(seed: int, threshold: int, base_w: float) -> None:
    lo = hot_tenant_p99(seed, threshold, base_w)
    hi = hot_tenant_p99(seed, threshold, 2.0 * base_w)
    assert hi <= lo * 1.001 + 1e-6, (
        f"doubling weight {base_w} raised hot-tenant p99 "
        f"{lo:.1f} -> {hi:.1f} (seed={seed}, threshold={threshold})")


class TestWeightMonotone:
    @given(st.integers(0, 5), st.sampled_from([4, 8]),
           st.sampled_from([1.0, 2.0]))
    @settings(max_examples=8, deadline=None)
    def test_doubling_weight_never_raises_p99(self, seed, threshold,
                                              base_w):
        check_weight_monotone(seed, threshold, base_w)


# ---------------------------------------------------------------------------
# rejected submissions are inert
# ---------------------------------------------------------------------------

def check_rejects_inert(seed: int) -> None:
    rng = np.random.default_rng(seed)
    rows = 32
    table = rng.integers(0, 2 ** 10, size=(rows,)).astype(np.int32)
    before = table.copy()
    cap = int(rng.integers(1, 4))
    n_sub = cap + int(rng.integers(1, 5))     # strictly over the cap
    sched = Scheduler(engine=_ENGINE)
    sched.configure_tenant("capped", max_pending=cap)

    subs = []
    tickets = []
    for _ in range(n_sub):
        idx = rng.integers(0, rows, size=8).astype(np.int32)
        vals = rng.integers(0, 2 ** 8, size=8).astype(np.int32)
        t = sched.submit_rmw(table, idx, vals, op="ADD", tenant="capped")
        tickets.append(t)
        subs.append((idx, vals, isinstance(sched.poll(t), QueueFull)))
    assert sum(r for _, _, r in subs) == n_sub - cap

    rep = sched.flush()
    got = np.asarray(sched.result(tickets[0]))
    want = before.copy()
    for idx, vals, rejected in subs:
        if rejected:
            continue                           # must leave no trace
        np.add.at(want, idx, vals)
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(table, before)   # caller's array intact
    assert rep.order and all(t == "capped" for t, _ in rep.order)


class TestRejectsInert:
    @given(st.integers(0, 10_000))
    @settings(**_small)
    def test_queue_full_never_mutates_tables(self, seed):
        check_rejects_inert(seed)
