"""Open-loop traffic serving: generator determinism, replay parity vs the
NumPy oracle, adaptive flush-window controllers, WFQ + admission control
through the service, telemetry, and the auto-flush/DecoupledLoop
regression.

The core invariant (ISSUE #6): window sizing and weighted-fair queueing
decide *when* work runs, never *what* it computes — every replayed
ticket must match the oracle bit-exactly however the controller cuts the
trace into windows. Mesh variants re-run the same replay on a sharded
engine (skipped below 4 visible devices; CI's sharded/traffic jobs force
8 host devices via XLA_FLAGS).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scheduler import QueueFull, QueueFullError
from repro.pipeline import DecoupledLoop
from repro.serve import (AccessService, AdaptiveFlushController,
                         FixedWindowController, Telemetry, Trace,
                         TrafficConfig, generate_trace)
from repro.testing import check_traffic_parity, generate_traffic_case

N_DEV = len(jax.devices())
TILE = 256

_ENGINE = []     # one shared single-device Engine for the whole module:
#                  services get fresh Schedulers (queue state) but reuse
#                  compiled executables instead of piling them up per test


def _scheduler():
    from repro.core import Engine, Scheduler
    if not _ENGINE:
        _ENGINE.append(Engine(tile_size=TILE))
    return Scheduler(engine=_ENGINE[0])


def adaptive_service(**kw):
    return AccessService(_scheduler(), auto_flush=0,
                         controller=AdaptiveFlushController(
                             overhead_us=200.0, **kw))


# ---------------------------------------------------------------------------
# generator
# ---------------------------------------------------------------------------

class TestGenerator:
    def test_deterministic(self):
        a = generate_traffic_case(7)
        b = generate_traffic_case(7)
        assert a.digest() == b.digest()
        assert len(a.events) == len(b.events)
        for e1, e2 in zip(a.events, b.events):
            assert (e1.t_us, e1.kind, e1.tenant, e1.table) == \
                (e2.t_us, e2.kind, e2.tenant, e2.table)

    def test_arrivals_monotone_and_bursty(self):
        cfg = TrafficConfig(seed=3, n_events=800, idle_gap_us=500.0,
                            burst_factor=100.0)
        tr = generate_trace(cfg)
        ts = np.array([e.t_us for e in tr.events])
        assert (np.diff(ts) >= 0).all()
        gaps = np.diff(ts)
        # bimodal: burst gaps an order of magnitude under idle gaps
        assert (gaps < cfg.idle_gap_us / 10).sum() > 50
        assert (gaps > cfg.idle_gap_us / 2).sum() > 50

    def test_zipf_tenant_skew(self):
        tr = generate_trace(TrafficConfig(seed=0, n_events=1500,
                                          n_tenants=2000))
        counts = {}
        for e in tr.events:
            counts[e.tenant] = counts.get(e.tenant, 0) + 1
        top = sorted(counts.values(), reverse=True)
        # zipf-skewed: the hot tenant dominates, yet the tail is wide
        assert top[0] > 20 * top[len(top) // 2]
        assert len(counts) > 100

    def test_rmw_tables_single_op_and_disjoint(self):
        tr = generate_traffic_case(1)
        for e in tr.events:
            if e.kind == "rmw":
                assert e.table.startswith("R")
                assert e.op == tr.table_ops[e.table]
            elif e.kind == "gather":
                assert e.table.startswith("G")

    def test_json_round_trip_and_digest_pinning(self):
        tr = generate_trace(TrafficConfig(seed=5, n_events=100))
        doc = tr.to_json()
        tr2 = Trace.from_json(doc)
        assert tr2.digest() == tr.digest()
        bad = doc.replace(tr.digest(), "0" * 16)
        with pytest.raises(ValueError, match="digest mismatch"):
            Trace.from_json(bad)


# ---------------------------------------------------------------------------
# replay parity (the satellite's core assertion)
# ---------------------------------------------------------------------------

class TestReplayParity:
    @pytest.mark.parametrize("seed", [0, 3])
    def test_adaptive_windows_bit_exact(self, seed):
        trace = generate_traffic_case(seed)
        checked, res = check_traffic_parity(trace,
                                            adaptive_service())
        assert checked > 0
        assert res.n_flushes > 1                  # actually windowed

    def test_fixed_window_drain_limited_bit_exact(self):
        trace = generate_traffic_case(2)
        svc = AccessService(_scheduler(), auto_flush=0,
                            controller=FixedWindowController(
                                6, drain_cap=4))
        checked, res = check_traffic_parity(trace, svc)
        assert checked > 0
        # drain cap actually deferred leaves across windows
        assert svc.scheduler.stats["deferrals"] > 0

    def test_weights_and_caps_bit_exact(self):
        trace = generate_traffic_case(4)
        svc = adaptive_service()
        counts = {}
        for e in trace.events:
            counts[e.tenant] = counts.get(e.tenant, 0) + 1
        hot = max(counts, key=counts.get)
        svc.connect(hot, weight=4.0, max_pending=3)
        checked, res = check_traffic_parity(trace, svc)
        assert checked > 0

    def test_mesh1_bit_exact(self):
        trace = generate_trace(TrafficConfig(seed=11, n_events=120,
                                             p_program=0.0))
        svc = AccessService(tile_size=TILE, auto_flush=0, mesh=1,
                            controller=AdaptiveFlushController(
                                overhead_us=200.0))
        checked, _ = check_traffic_parity(trace, svc)
        assert checked > 0

    @pytest.mark.skipif(N_DEV < 4, reason="needs 4 devices: set "
                        "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    def test_mesh4_bit_exact(self):
        trace = generate_trace(TrafficConfig(seed=11, n_events=120,
                                             p_program=0.0))
        svc = AccessService(tile_size=TILE, auto_flush=0, mesh=4,
                            controller=AdaptiveFlushController(
                                overhead_us=200.0))
        checked, _ = check_traffic_parity(trace, svc)
        assert checked > 0


# ---------------------------------------------------------------------------
# controllers
# ---------------------------------------------------------------------------

class TestAdaptiveController:
    def test_target_deepens_with_arrival_rate(self):
        slow = AdaptiveFlushController(overhead_us=200.0)
        fast = AdaptiveFlushController(overhead_us=200.0)
        for k in range(50):
            slow.observe_submit(k * 1000.0)
            fast.observe_submit(k * 5.0)
        assert slow.target_depth() <= 2
        assert fast.target_depth() >= 16

    def test_target_clamped(self):
        c = AdaptiveFlushController(min_window=2, max_window=8,
                                    overhead_us=200.0)
        for k in range(100):
            c.observe_submit(k * 0.5)        # absurd rate
        assert c.target_depth() == 8
        c2 = AdaptiveFlushController(min_window=2, max_window=8,
                                     overhead_us=200.0)
        assert c2.target_depth() == 2        # no observations yet

    def test_overhead_ewma_tracks_measured_durations(self):
        c = AdaptiveFlushController()          # not pinned
        before = c.snapshot()["overhead_us"]
        for _ in range(40):
            c.observe_flush(4, 1000.0, None, 0.0)
        assert c.snapshot()["overhead_us"] > before * 2
        pinned = AdaptiveFlushController(overhead_us=123.0)
        for _ in range(40):
            pinned.observe_flush(4, 9999.0, None, 0.0)
        assert pinned.snapshot()["overhead_us"] == 123.0

    def test_deadline_lifecycle(self):
        c = AdaptiveFlushController(max_wait_us=100.0, overhead_us=200.0)
        assert c.deadline() is None
        c.observe_submit(50.0)
        assert c.deadline() == 150.0
        c.observe_submit(90.0)                 # oldest wins
        assert c.deadline() == 150.0
        assert not c.should_flush(1, 149.0)
        assert c.should_flush(1, 150.0)
        c.observe_flush(2, 10.0, None, 160.0)  # full drain clears
        assert c.deadline() is None
        c.observe_submit(200.0)
        c.observe_flush(1, 10.0, None, 210.0, pending_after=3)
        assert c.deadline() == 310.0           # deferral restarts wait

    def test_never_flushes_empty(self):
        c = AdaptiveFlushController(overhead_us=200.0)
        c.observe_submit(0.0)
        assert not c.should_flush(0, 1e9)


class TestFixedController:
    def test_threshold_and_deadline(self):
        c = FixedWindowController(4, max_wait_us=100.0)
        assert c.target_depth() == 4
        c.observe_submit(0.0)
        assert not c.should_flush(3, 50.0)
        assert c.should_flush(4, 50.0)
        assert c.should_flush(1, 100.0)        # deadline

    def test_drain_cap(self):
        c = FixedWindowController(4, drain_cap=4)
        assert c.drain_limit(10) == 4
        assert c.drain_limit(2) == 2
        assert FixedWindowController(4).drain_limit(10) is None


class TestTick:
    def test_forced_tick_flushes_empty_window(self):
        svc = adaptive_service()
        rep = svc.tick(force=True)             # zero pending: harmless
        assert rep is not None and rep.order == ()
        s = svc.stats()
        assert s["traffic"]["windows"]["n_flushes"] == 1
        assert s["traffic"]["windows"]["depth_hist"].get("0") == 1

    def test_tick_fires_on_deadline_only(self):
        clock = {"now": 0.0}
        svc = AccessService(_scheduler(), auto_flush=0,
                            controller=AdaptiveFlushController(
                                min_window=4, max_wait_us=100.0,
                                overhead_us=200.0),
                            clock=lambda: clock["now"])
        assert svc.tick() is None              # nothing pending
        T = np.arange(32, dtype=np.float32)
        t = svc.submit_gather(T, np.arange(4), tenant="a")
        clock["now"] = 50.0
        assert svc.tick() is None              # deadline not reached
        clock["now"] = 101.0
        rep = svc.tick()
        assert rep is not None and len(rep.order) == 1
        np.testing.assert_array_equal(np.asarray(svc.wait(t)), T[:4])


# ---------------------------------------------------------------------------
# WFQ + admission through the service
# ---------------------------------------------------------------------------

class TestServicePolicy:
    def test_weights_drive_drain_order(self):
        svc = AccessService(_scheduler(), auto_flush=0)
        heavy = svc.connect("heavy", weight=4.0)
        light = svc.connect("light")
        T = np.arange(64, dtype=np.float32)
        for k in range(3):
            light.submit_gather(T, np.arange(4))
            heavy.submit_gather(T, np.arange(4))
        rep = svc.flush()
        tenants = [t for t, _ in rep.order]
        assert tenants[:3] == ["heavy", "heavy", "heavy"]

    def test_equal_weights_stay_round_robin(self):
        svc = AccessService(_scheduler(), auto_flush=0)
        T = np.arange(64, dtype=np.float32)
        for tenant in ("a", "b", "a", "c"):
            svc.submit_gather(T, np.arange(4), tenant=tenant)
        rep = svc.flush()
        assert [t for t, _ in rep.order] == ["a", "b", "c", "a"]

    def test_drain_limit_splits_by_weight(self):
        svc = AccessService(_scheduler(), auto_flush=0)
        svc.connect("a", weight=3.0)
        T = np.arange(64, dtype=np.float32)
        for _ in range(8):
            svc.submit_gather(T, np.arange(4), tenant="a")
            svc.submit_gather(T, np.arange(4), tenant="b")
        rep = svc.flush(drain_limit=4)
        tenants = [t for t, _ in rep.order]
        assert tenants.count("a") == 3 and tenants.count("b") == 1
        # deferred leaves drain on the next flush; nothing is lost
        rep2 = svc.flush(inflight_ok=True)
        assert len(rep2.order) == 12

    def test_admission_cap_rejects_and_recovers(self):
        svc = AccessService(_scheduler(), auto_flush=0)
        core = svc.connect("small", max_pending=2)
        T = np.arange(64, dtype=np.float32)
        t1 = core.submit_gather(T, np.arange(4))
        t2 = core.submit_gather(T, np.arange(4))
        t3 = core.submit_gather(T, np.arange(4))
        assert isinstance(svc.poll(t3), QueueFull)
        with pytest.raises(QueueFullError):
            svc.wait(t3)
        s = svc.stats()
        assert s["rejects"] == 1
        assert s["traffic"]["tenants"]["small"]["rejects"] == 1
        svc.flush(inflight_ok=True)
        np.testing.assert_array_equal(np.asarray(svc.wait(t1)), T[:4])
        np.testing.assert_array_equal(np.asarray(svc.wait(t2)), T[:4])
        t4 = core.submit_gather(T, np.arange(4))   # capacity freed
        assert not isinstance(svc.poll(t4), QueueFull)

    def test_stats_is_a_method_with_serving_sections(self):
        svc = adaptive_service()
        s = svc.stats()
        assert "traffic" in s and "controller" in s and "engine" in s
        assert s["controller"]["kind"] == "AdaptiveFlushController"


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------

class _T:
    def __init__(self, tid, tenant):
        self.tid, self.tenant = tid, tenant


class TestTelemetry:
    def test_latency_interpolates_across_drain_order(self):
        tel = Telemetry()
        tel.on_submit(_T(0, "a"), 0.0)
        tel.on_submit(_T(1, "b"), 0.0)
        tel.on_flush([("a", 0), ("b", 1)], 100.0, 300.0)
        # position 0 completes at 200, position 1 at 300
        assert tel.tenant_stats("a").p50_us == pytest.approx(200.0)
        assert tel.tenant_stats("b").p50_us == pytest.approx(300.0)

    def test_depth_histogram_buckets(self):
        tel = Telemetry()
        for d in (0, 1, 2, 3, 4, 5, 9, 64):
            tel.on_flush([("a", -1)] * 0, 0.0, 0.0, pending_before=d)
        h = tel.depth_histogram()
        assert h == {"0": 1, "1": 1, "2": 1, "3-4": 2, "5-8": 1,
                     "9-16": 1, "33-64": 1}

    def test_summary_and_render(self):
        tel = Telemetry()
        for k in range(10):
            tel.on_submit(_T(k, f"t{k % 2}"), float(k))
        tel.on_reject("t9", 10.0)
        tel.on_flush([(f"t{k % 2}", k) for k in range(10)], 10.0, 20.0)
        s = tel.summary()
        assert s["overall"]["n_completed"] == 10
        assert s["overall"]["rejects"] == 1
        assert s["overall"]["throughput_per_s"] > 0
        out = tel.render()
        assert "p99" in out and "worst-p99 tenants" in out

    def test_unknown_tickets_skipped(self):
        tel = Telemetry()
        tel.on_flush([("ghost", 999)], 0.0, 10.0)
        assert tel.n_completed == 0


# ---------------------------------------------------------------------------
# regression: auto-flush vs unresolved flush_async handles (ISSUE #6 fix
# satellite) — overlapping windows only ever via inflight_ok=True opt-in
# ---------------------------------------------------------------------------

class TestAutoFlushDecoupledRegression:
    def _run(self, svc):
        rng = np.random.default_rng(0)
        table = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
        perm = rng.permutation(64).astype(np.int32)
        side = []

        def access(loop, k, state):
            # open-loop side traffic lands mid-window: trips the
            # service's auto-flush while the loop's previous window
            # handle is still unresolved
            for i in range(3):
                idx = perm[8 * i:8 * i + 8]
                side.append((svc.submit_gather(table, idx,
                                               tenant="side"), idx))
            return loop.submit_gather(state, perm)

        def compute(k, state, xg):
            return xg

        out = DecoupledLoop(svc).run(table, 6, access, compute)
        want = np.asarray(table)
        for _ in range(6):
            want = want[perm]
        np.testing.assert_array_equal(np.asarray(out), want)
        # no ticket dropped: every side submission redeems exactly
        for t, idx in side:
            np.testing.assert_array_equal(np.asarray(svc.wait(t)),
                                          np.asarray(table)[idx])
        assert len(side) == 18

    def test_auto_flush_threshold_interleaves_safely(self):
        self._run(AccessService(_scheduler(), auto_flush=2))

    def test_controller_interleaves_safely(self):
        self._run(AccessService(
            _scheduler(), auto_flush=0,
            controller=FixedWindowController(2, max_wait_us=1e12)))


# ---------------------------------------------------------------------------
# nightly soak (longer trace; the CI traffic job runs it under --runslow
# with 8 forced host devices)
# ---------------------------------------------------------------------------

@pytest.mark.slow
class TestTrafficSoak:
    def test_long_trace_parity(self):
        trace = generate_trace(TrafficConfig(
            seed=42, n_events=4000, n_tenants=2000, p_program=0.02))
        checked, res = check_traffic_parity(trace, adaptive_service())
        assert checked > 3500
        assert res.n_flushes > 50

    @pytest.mark.skipif(N_DEV < 4, reason="needs 4 devices")
    def test_long_trace_parity_mesh4(self):
        trace = generate_trace(TrafficConfig(
            seed=43, n_events=1000, n_tenants=2000, p_program=0.0))
        svc = AccessService(tile_size=TILE, auto_flush=0, mesh=4,
                            controller=AdaptiveFlushController(
                                overhead_us=200.0))
        checked, _ = check_traffic_parity(trace, svc)
        assert checked > 900
