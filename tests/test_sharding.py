"""Sharding-rule units + a tiny-mesh integration test (runs on 1 CPU
device; the production meshes are exercised by launch/dryrun.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import mesh as meshlib
from repro.models import build_model


@pytest.fixture(scope="module")
def tiny_mesh():
    # 1 device -> (1, 1) mesh: exercises the full sharding path end to end
    return meshlib.make_host_mesh(1, 1)


class TestParamSpecs:
    def test_rules_applied_with_stacking(self, tiny_mesh):
        cfg = get_config("qwen3-0.6b").reduced()
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        specs = meshlib.param_specs(shapes, tiny_mesh)
        # embedding: vocab over model (address-range partitioning)
        assert tuple(specs["embed"]) == ("model", None)
        # stacked layer kernels get a leading None for the scan dim
        assert tuple(specs["layers"]["attn"]["wq"]) == (None, None, "model")
        assert tuple(specs["layers"]["mlp"]["w_down"]) == (None, "model",
                                                           None)
        # norms replicated (P(None) == P(): no mesh axis assigned)
        assert all(ax is None for ax in tuple(specs["final_norm"]))

    def test_moe_expert_sharding(self, tiny_mesh):
        cfg = get_config("dbrx-132b").reduced()
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        specs = meshlib.param_specs(shapes, tiny_mesh)
        # experts over `model` (EP), stacked under layers
        assert tuple(specs["layers"]["moe"]["w_gate"]) == (
            None, "model", None, None)
        assert tuple(specs["layers"]["moe"]["router"])[-1] is None

    def test_nondivisible_dims_replicated(self):
        mesh = meshlib.make_host_mesh(1, 1)
        # fabricate a mesh dict: model=16 against a 9-head (=576) dim is
        # checked by the production mesh; here verify the divisibility
        # logic via a fake leaf on the 1x1 mesh (everything divides by 1)
        cfg = get_config("smollm-135m").reduced()
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        specs = meshlib.param_specs(shapes, mesh)
        assert tuple(specs["embed"]) == ("model", None)

    def test_zero1_adds_data_axis(self, tiny_mesh):
        cfg = get_config("qwen3-0.6b").reduced()
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        pspecs = meshlib.param_specs(shapes, tiny_mesh)
        zspecs = meshlib.zero1_specs(pspecs, shapes, tiny_mesh)
        spec = tuple(zspecs["layers"]["attn"]["wq"])
        assert "data" in spec and "model" in spec

    def test_cache_specs_find_batch_dim(self, tiny_mesh):
        cfg = get_config("jamba-1.5-large-398b").reduced()
        model = build_model(cfg)
        cache = jax.eval_shape(lambda: model.init_cache(4, 64))
        specs = meshlib.cache_specs(cache, tiny_mesh, 4)
        # hybrid conv state is (nsb, nmamba, B, k, d): batch at index 2
        conv_spec = tuple(specs["conv"])
        assert conv_spec[2] == ("data",) or conv_spec[2] == "data" \
            or conv_spec[2] == ("data",)

    def test_batch_specs_replicate_non_divisible(self, tiny_mesh):
        batch = {"tokens": jax.ShapeDtypeStruct((3, 8), jnp.int32)}
        specs = meshlib.batch_specs(batch, tiny_mesh)
        # 3 % 1 == 0 on the 1x1 mesh: sharded over data
        assert tuple(specs["tokens"])[0] in ("data", ("data",))


class TestShardedTrainStep:
    def test_train_step_on_host_mesh(self, tiny_mesh):
        """Full sharded train step executes on the (1,1) mesh."""
        from repro.data import make_batch
        from repro.optim import adamw_init
        from repro.train.trainer import shard_train_step

        cfg = get_config("smollm-135m").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw_init(params)
        batch = make_batch(cfg, batch=2, seq=16, kind="train")
        pshape = jax.eval_shape(lambda: params)
        oshape = jax.eval_shape(lambda: opt)
        bshape = jax.eval_shape(lambda: batch)
        step = shard_train_step(model, tiny_mesh, pshape, oshape, bshape)
        params2, opt2, metrics = step(params, opt, batch)
        assert jnp.isfinite(metrics["loss"])
        assert int(opt2["step"]) == 1
