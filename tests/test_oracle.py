"""Unit tests for the NumPy oracle itself (repro.testing.oracle).

The oracle is ground truth for everything else, so it gets its own
known-answer tests, plus meta-tests showing the parity harness actually
*detects* seeded divergence (a differential tester that can't fail is
worthless).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import isa
from repro.core.compiler import Access, Load, Pattern, RangeLoop, Var
from repro.core.engine import Engine
from repro.testing import harness, oracle
from repro.testing.oracle import OracleEngine


def _prog(*instrs, tile_size=8):
    return isa.AccessProgram(tuple(instrs), tile_size=tile_size)


class TestOracleInstructions:
    def test_sld_strided(self):
        p = _prog(isa.SLD("i32", "A", "t", rs1=2, rs3=3))
        env = {"A": np.arange(100, dtype=np.int32)}
        _, spd = OracleEngine(8).run(p, env)
        np.testing.assert_array_equal(spd["t"], 2 + 3 * np.arange(8))

    def test_sld_clips_at_region_end(self):
        p = _prog(isa.SLD("i32", "A", "t", rs1=0, rs3=1))
        env = {"A": np.arange(5, dtype=np.int32)}
        _, spd = OracleEngine(8).run(p, env)
        np.testing.assert_array_equal(spd["t"], [0, 1, 2, 3, 4, 4, 4, 4])

    def test_ild_gather_and_cond(self):
        p = _prog(isa.SLD("i32", "B", "idx", rs1=0),
                  isa.ILD("f32", "A", "out", "idx", tc="mask"))
        env = {"A": np.arange(8, dtype=np.float32) * 2.0,
               "B": np.asarray([3, 1, 0, 2, 7, 6, 5, 4], np.int32)}
        spd0 = {"mask": np.asarray([1, 1, 0, 1, 1, 1, 1, 0], np.int32)}
        _, spd = OracleEngine(8).run(p, env, spd=spd0)
        want = np.asarray([6, 2, 0, 4, 14, 12, 10, 0], np.float32)
        want[2] = 0.0
        np.testing.assert_array_equal(spd["out"], want)

    def test_ist_last_write_wins(self):
        p = _prog(isa.IST("f32", "A", "idx", "val"))
        env = {"A": np.zeros(8, np.float32)}
        spd0 = {"idx": np.asarray([1, 1, 2, 1, 0, 0, 3, 3], np.int32),
                "val": np.arange(8, dtype=np.float32) + 1}
        env2, _ = OracleEngine(8).run(p, env, spd=spd0)
        np.testing.assert_array_equal(env2["A"],
                                      [6, 4, 3, 8, 0, 0, 0, 0])

    def test_irmw_sequential_and_oob_drop(self):
        p = _prog(isa.IRMW("i32", "A", "ADD", "idx", "val"))
        env = {"A": np.zeros(4, np.int32)}
        spd0 = {"idx": np.asarray([0, 0, 3, 99, -1, 2, 2, 2], np.int32),
                "val": np.ones(8, np.int32)}
        env2, _ = OracleEngine(8).run(p, env, spd=spd0)
        np.testing.assert_array_equal(env2["A"], [2, 0, 3, 1])

    def test_irmw_integer_wraparound(self):
        p = _prog(isa.IRMW("i32", "A", "MUL", "idx", "val"))
        env = {"A": np.full(2, 2 ** 30, np.int32)}
        spd0 = {"idx": np.zeros(8, np.int32),
                "val": np.full(8, 3, np.int32)}
        env2, _ = OracleEngine(8).run(p, env, spd=spd0)
        # must wrap modulo 2^32 silently, like XLA
        assert env2["A"][0] == np.int32(2 ** 30 * 3 ** 8 & 0xFFFFFFFF)

    def test_rng_truncates_at_capacity(self):
        p = _prog(isa.RNG("o", "j", "lo", "hi", rs1=4))
        spd0 = {"lo": np.zeros(8, np.int32),
                "hi": np.full(8, 3, np.int32)}
        _, spd = OracleEngine(8).run(p, {}, spd=spd0)
        assert int(spd["_rng_total"]) == 4
        np.testing.assert_array_equal(spd["o"], [0, 0, 0, 1])
        np.testing.assert_array_equal(spd["j"], [0, 1, 2, 0])
        np.testing.assert_array_equal(spd["o__mask"], [1, 1, 1, 1])

    def test_alu_matches_engine_bitwise(self):
        p = _prog(isa.ALUV("i32", "XOR", "c", "a", "b"),
                  isa.ALUS("i32", "SHR", "d", "c", rs=2))
        spd0 = {"a": np.arange(8, dtype=np.int32) * 7,
                "b": np.asarray([3] * 8, np.int32)}
        _, ospd = OracleEngine(8).run(p, {}, spd=spd0)
        _, espd = Engine(tile_size=8).run(
            p, {}, spd={k: jnp.asarray(v) for k, v in spd0.items()})
        np.testing.assert_array_equal(ospd["d"], np.asarray(espd["d"]))


class TestSourceEvaluator:
    def test_plain_gather_store(self):
        env = {"B": np.asarray([2, 0, 1], np.int32),
               "A": np.asarray([10., 20., 30.], np.float32),
               "out": np.zeros(3, np.float32)}
        pat = Pattern([Access("ST", "out", Var("i"),
                              value=Load("A", Load("B", Var("i"))),
                              dtype="f32")], name="t")
        env2, _ = oracle.run_pattern(pat, env, n=3)
        np.testing.assert_array_equal(env2["out"], [30., 10., 20.])

    def test_range_loop_rowsum(self):
        env = {"H": np.asarray([0, 2, 2, 5], np.int32),
               "V": np.arange(5, dtype=np.float32) + 1,
               "y": np.zeros(3, np.float32)}
        from repro.core.compiler import BinOp
        pat = Pattern([Access("RMW", "y", Var("i"),
                              value=Load("V", Var("j")), op="ADD",
                              dtype="f32")],
                      range_loop=RangeLoop(
                          "j", Load("H", Var("i")),
                          Load("H", BinOp("ADD", Var("i"), 1))),
                      name="rowsum")
        env2, _ = oracle.run_pattern(pat, env, n=3)
        np.testing.assert_array_equal(env2["y"], [3., 0., 12.])

    def test_loads_stream_masked_by_cond(self):
        from repro.core.compiler import Compare
        env = {"A": np.arange(4, dtype=np.float32),
               "D": np.asarray([1., -1., 1., -1.], np.float32),
               "s": np.zeros(4, np.float32)}
        pat = Pattern([Access("LD", "A", Var("i"), dtype="f32",
                              cond=Compare("GT", Load("D", Var("i")), 0.0)),
                       Access("ST", "s", Var("i"),
                              value=Load("D", Var("i")), dtype="f32")],
                      name="condld")
        _, loads = oracle.run_pattern(pat, env, n=4)
        np.testing.assert_array_equal(loads["A"], [0., 0., 2., 0.])


class TestHarnessDetectsBugs:
    """Meta-tests: the differential harness must flag real divergence."""

    def test_mismatch_raises(self):
        got = np.asarray([1, 2, 3], np.int32)
        want = np.asarray([1, 9, 3], np.int32)
        with pytest.raises(harness.ParityError):
            harness._assert_match("t", got, want, rtol=0, atol=0)

    def test_broken_engine_is_caught(self, monkeypatch):
        """Sabotage bulk_scatter's duplicate policy; parity must fail."""
        from repro.core import bulk_ops
        from repro.testing import conformance
        real = bulk_ops.bulk_scatter

        def first_write_wins(table, idx, values, cond=None, optimize=True):
            return real(table, idx[::-1], values[::-1], cond=None if
                        cond is None else cond[::-1], optimize=optimize)
        monkeypatch.setattr(
            "repro.core.engine.bulk_ops.bulk_scatter", first_write_wins)
        case = conformance.build("hashjoin_build")
        with pytest.raises(harness.ParityError):
            harness.check_pattern_parity(
                case.pattern, case.env, n=case.n,
                configs=[harness.EngineConfig(True, False, False, 64)])

    def test_oracle_engine_agreement_on_seed_program(self):
        """Direct spot check: engine vs oracle on a hand-built program."""
        rng = np.random.default_rng(3)
        prog = _prog(
            isa.SLD("i32", "B", "idx", rs1=0),
            isa.ILD("f32", "A", "v", "idx"),
            isa.ALUS("f32", "MUL", "v2", "v", rs=2.0),
            isa.IST("f32", "out", "idx", "v2"),
            tile_size=16)
        env = {"A": rng.normal(size=32).astype(np.float32),
               "B": rng.integers(0, 32, size=16).astype(np.int32),
               "out": np.zeros(32, np.float32)}
        oenv, ospd = OracleEngine(16).run(prog, env)
        eenv, espd = Engine(tile_size=16).run(
            prog, {k: jnp.asarray(v) for k, v in env.items()})
        np.testing.assert_allclose(np.asarray(eenv["out"]), oenv["out"],
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(espd["v2"]), ospd["v2"],
                                   rtol=1e-6)
