"""Property suite for the exchange codecs (DESIGN.md §5 step 4).

Pins the contract the sharded engine's wire format depends on: after
``dedup_stream`` + ``partition_by_owner``, every bucket is a strictly
ascending run of distinct local rows, and both index codecs round-trip
that run **exactly** (set semantics) at any mesh size — including over
adversarial streams (empty, all-duplicate, monotone, zipf-skewed, and
OOB-poisoned). The primitives are collective-free, so everything here
runs on a single device.

The randomized half uses ``hypothesis`` when available and skips
cleanly when not; the deterministic adversarial cases always run.
"""
import numpy as np
import pytest

jnp = pytest.importorskip("jax.numpy")

from repro.distributed import exchange  # noqa: E402

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

MESHES = (1, 2, 4, 8)


def _oracle_buckets(idx, valid, *, rows_per, num_shards):
    """Per-owner sorted unique local rows — what a decoder must recover."""
    h = np.asarray(idx)[np.asarray(valid)]
    owner = np.clip(h // rows_per, 0, num_shards - 1)
    return [np.unique(h[owner == o]) - o * rows_per
            for o in range(num_shards)]


def _roundtrip(codec, idx, valid, *, rows_per, num_shards):
    """dedup -> partition -> encode -> decode; assert exact set recovery
    and that the wire cost matches ``codec_wire_words``."""
    want = _oracle_buckets(idx, valid, rows_per=rows_per,
                           num_shards=num_shards)
    cap = exchange.bucket_capacity(max((w.shape[0] for w in want),
                                       default=0))
    u_idx, u_valid, _, _ = exchange.dedup_stream(
        jnp.asarray(idx.astype(np.int32)), jnp.asarray(valid))
    send_idx, send_valid, _, _, sent = exchange.partition_by_owner(
        u_idx, u_valid, rows_per=rows_per, num_shards=num_shards,
        capacity=cap)
    np.testing.assert_array_equal(
        np.asarray(sent), [w.shape[0] for w in want])
    enc, dec, _ = exchange.CODECS[codec]
    words = enc(send_idx, send_valid, rows_per=rows_per,
                num_shards=num_shards)
    assert words.shape[0] == num_shards * exchange.codec_wire_words(
        codec, rows_per=rows_per, capacity=cap)
    local, lvalid = dec(words, rows_per=rows_per, num_shards=num_shards,
                        capacity=cap)
    local, lvalid = np.asarray(local), np.asarray(lvalid)
    for o in range(num_shards):
        got = np.sort(local[o * cap:(o + 1) * cap]
                      [lvalid[o * cap:(o + 1) * cap]])
        np.testing.assert_array_equal(got, want[o], err_msg=(
            f"codec={codec} owner={o} mesh={num_shards}"))


def _adversarial_streams(rows):
    rng = np.random.default_rng(0)
    zipf = np.minimum(rng.zipf(1.3, size=256) - 1, rows - 1)
    poisoned = rng.integers(-rows, 2 * rows, size=200)
    return {
        "empty": (np.zeros(16, np.int64), np.zeros(16, bool)),
        "all_dup": (np.full(64, rows // 2), np.ones(64, bool)),
        "monotone": (np.arange(rows), np.ones(rows, bool)),
        "zipf": (zipf, np.ones(zipf.shape[0], bool)),
        # OOB lanes arrive masked invalid (the engine's RMW discipline);
        # the codecs must not let their garbage perturb any bucket
        "oob_poisoned": (poisoned, (poisoned >= 0) & (poisoned < rows)),
    }


@pytest.mark.parametrize("codec", sorted(exchange.CODECS))
@pytest.mark.parametrize("name", sorted(_adversarial_streams(256)))
@pytest.mark.parametrize("mesh", MESHES)
def test_codec_roundtrip_adversarial(codec, name, mesh):
    rows = 256
    idx, valid = _adversarial_streams(rows)[name]
    _roundtrip(codec, idx, valid, rows_per=-(-rows // mesh),
               num_shards=mesh)


def test_delta_rejects_wide_tables():
    """16-bit packed deltas are only legal for rows_per <= 65536 — the
    static guarantee the cost model relies on when it offers "delta"."""
    with pytest.raises(ValueError, match="65536"):
        exchange.encode_delta(jnp.zeros(8, jnp.int32),
                              jnp.zeros(8, bool),
                              rows_per=(1 << 16) + 1, num_shards=1)


def test_dedup_stream_contract():
    """First n_u lanes strictly ascending; inv restores the stream."""
    rng = np.random.default_rng(5)
    idx = rng.integers(0, 40, size=128).astype(np.int32)
    valid = rng.random(128) < 0.8
    u_idx, u_valid, inv, n_u = exchange.dedup_stream(
        jnp.asarray(idx), jnp.asarray(valid))
    u_idx, n_u = np.asarray(u_idx), int(n_u)
    assert n_u == np.unique(idx[valid]).shape[0]
    np.testing.assert_array_equal(u_idx[:n_u], np.unique(idx[valid]))
    assert np.asarray(u_valid).sum() == n_u
    restored = u_idx[np.asarray(inv)]
    np.testing.assert_array_equal(restored[valid], idx[valid])


def test_combine_duplicates_matches_segment_oracle():
    rng = np.random.default_rng(6)
    idx = rng.integers(0, 24, size=96).astype(np.int32)
    vals = rng.integers(1, 9, size=96).astype(np.int32)
    valid = rng.random(96) < 0.7
    u_idx, u_vals, u_valid, n_u = exchange.combine_duplicates(
        jnp.asarray(idx), jnp.asarray(vals), jnp.asarray(valid), op="ADD")
    u_idx, u_vals, n_u = np.asarray(u_idx), np.asarray(u_vals), int(n_u)
    want_keys = np.unique(idx[valid])
    np.testing.assert_array_equal(u_idx[:n_u], want_keys)
    want = np.array([vals[valid & (idx == k)].sum() for k in want_keys])
    np.testing.assert_array_equal(u_vals[:n_u], want)


if HAVE_HYPOTHESIS:

    stream = st.lists(st.integers(min_value=-64, max_value=320),
                      min_size=0, max_size=200)

    @settings(max_examples=30, deadline=None)
    @given(raw=stream, mesh=st.sampled_from(MESHES),
           codec=st.sampled_from(sorted(exchange.CODECS)))
    def test_codec_roundtrip_property(raw, mesh, codec):
        rows = 256
        idx = np.asarray(raw + [0], dtype=np.int64)  # never zero-length
        valid = (idx >= 0) & (idx < rows)
        _roundtrip(codec, idx, valid, rows_per=-(-rows // mesh),
                   num_shards=mesh)

    @settings(max_examples=30, deadline=None)
    @given(raw=stream)
    def test_dedup_is_sorted_unique_property(raw):
        idx = np.asarray(raw + [0], dtype=np.int64)
        valid = (idx >= 0) & (idx < 256)
        u_idx, _, _, n_u = exchange.dedup_stream(
            jnp.asarray(idx.astype(np.int32)), jnp.asarray(valid))
        np.testing.assert_array_equal(np.asarray(u_idx)[:int(n_u)],
                                      np.unique(idx[valid]))
