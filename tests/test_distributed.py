"""Distributed bulk-access engine: exchange units, oracle parity across
mesh sizes, and the Scheduler/serve integration.

Mesh sizes above the visible device count are skipped — run the full
matrix with XLA_FLAGS=--xla_force_host_platform_device_count=8 (the CI
``sharded`` job does)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Scheduler
from repro.core.compiler import Access, Load, Pattern, Var
from repro.distributed import (ShardedEngine, as_mesh, device_mesh,
                               masked_unique_count, partition_by_owner)
from repro.distributed.exchange import pack_payload, unpack_result
from repro.serve.access_service import AccessService
from repro.testing import harness

N_DEV = len(jax.devices())
MESH_SIZES = [m for m in (1, 2, 4, 8) if m <= N_DEV]
multidev = pytest.mark.skipif(
    N_DEV < 2, reason="single-device host: set "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8")


# ---------------------------------------------------------------------------
# exchange primitives (collective-free: run on any host)
# ---------------------------------------------------------------------------

class TestPartitionByOwner:
    def test_buckets_are_owner_pure_and_ordered(self):
        idx = jnp.asarray([7, 0, 12, 3, 9, 15, 1], jnp.int32)
        valid = jnp.ones((7,), bool)
        send_idx, send_valid, order, slot, sent = partition_by_owner(
            idx, valid, rows_per=4, num_shards=4)
        L = 7
        si, sv = np.asarray(send_idx), np.asarray(send_valid)
        for o in range(4):
            bucket = si[o * L:(o + 1) * L][sv[o * L:(o + 1) * L]]
            assert (bucket // 4 == o).all()
        # every valid index lands exactly once
        np.testing.assert_array_equal(np.sort(si[sv]), np.sort(np.asarray(idx)))
        np.testing.assert_array_equal(np.asarray(sent), [3, 1, 1, 2])

    def test_invalid_lanes_drop(self):
        idx = jnp.asarray([5, 99, 2, 99], jnp.int32)
        valid = jnp.asarray([True, False, True, False])
        send_idx, send_valid, _, _, sent = partition_by_owner(
            idx, valid, rows_per=8, num_shards=2)
        assert int(jnp.sum(send_valid)) == 2
        assert int(jnp.sum(sent)) == 2

    def test_payload_roundtrip(self):
        rng = np.random.default_rng(0)
        idx = jnp.asarray(rng.integers(0, 64, size=33), jnp.int32)
        valid = jnp.asarray(rng.random(33) < 0.8)
        _, send_valid, order, slot, _ = partition_by_owner(
            idx, valid, rows_per=16, num_shards=4)
        payload = jnp.asarray(rng.normal(size=33).astype(np.float32))
        bucket = pack_payload(payload, order, slot, num_shards=4)
        back = unpack_result(bucket, order, slot, valid)
        want = np.where(np.asarray(valid), np.asarray(payload), 0)
        np.testing.assert_array_equal(np.asarray(back), want)

    def test_masked_unique_count(self):
        idx = jnp.asarray([4, 4, 7, 2, 7, 9], jnp.int32)
        valid = jnp.asarray([True, True, True, True, True, False])
        assert int(masked_unique_count(idx, valid)) == 3
        assert int(masked_unique_count(idx, jnp.zeros(6, bool))) == 0


class TestMesh:
    def test_device_mesh_too_big_raises(self):
        with pytest.raises(ValueError, match="xla_force_host_platform"):
            device_mesh(N_DEV + 1)

    def test_as_mesh_accepts_int_none_mesh(self):
        m = device_mesh(1)
        assert as_mesh(m) is m
        assert as_mesh(1).shape == {"shards": 1}
        assert as_mesh(None).shape["shards"] == N_DEV
        with pytest.raises(TypeError):
            as_mesh("shards")


# ---------------------------------------------------------------------------
# oracle parity across mesh sizes (the acceptance criterion)
# ---------------------------------------------------------------------------

class TestShardedParity:
    def test_gather_rmw_parity_all_mesh_sizes(self):
        checked, ran = harness.check_sharded_parity(mesh_sizes=MESH_SIZES)
        assert ran == MESH_SIZES
        assert checked == len(harness.default_sharded_cases(0)) * len(ran)

    @pytest.mark.parametrize("seed", [1, 2])
    def test_fuzzed_streams(self, seed):
        checked, _ = harness.check_sharded_parity(
            cases=harness.default_sharded_cases(seed),
            mesh_sizes=MESH_SIZES, seed=seed)
        assert checked > 0

    def test_empty_stream_and_stats(self):
        eng = ShardedEngine(mesh=MESH_SIZES[-1])
        table = jnp.arange(32.0)
        out = eng.sharded_gather(table, jnp.zeros((0,), jnp.int32))
        assert out.shape == (0,)
        assert eng.last_shard_stats is None

    def test_shard_stats_accounting(self):
        """Stats are **post-dedup** (DESIGN.md §5): ``sent[i, j]`` counts
        distinct rows per (source slice, owner), every sent lane lands
        (the measured capacity is exact), and ``unique[j]`` — the global
        distinct rows owned by ``j`` — is placement-invariant."""
        m = MESH_SIZES[-1]
        rng = np.random.default_rng(3)
        idx = rng.integers(0, 96, size=200).astype(np.int32)
        rows_per = -(-96 // m)
        want_uniq = [np.unique(idx[idx // rows_per == o]).shape[0]
                     for o in range(m)]
        for placement in ("block", "owner"):
            eng = ShardedEngine(mesh=m)
            eng.sharded_gather(jnp.arange(96.0), jnp.asarray(idx),
                               placement=placement)
            st = eng.last_shard_stats
            assert st.placement == placement
            assert st.sent.shape == (m, m)
            # dedup-before-fabric: at most the distinct rows ship, and
            # nothing drops on the measured-capacity exchange
            assert int(st.sent.sum()) <= 200
            assert int(st.sent.sum()) == int(st.received.sum())
            assert int(st.sent.sum()) >= np.unique(idx).shape[0]
            np.testing.assert_array_equal(st.unique, want_uniq)
            assert (st.coalescing_gain >= 1).all()
            assert 0 <= st.local_fraction <= 1
            assert st.bytes_on_wire >= 0 and st.compression_ratio >= 1.0

    def test_owner_placement_raises_local_fraction(self):
        """The locality lever: on a blocked per-shard mix, owner-major
        placement keeps nearly every post-dedup lane on its owner while
        block placement scatters them."""
        m = MESH_SIZES[-1]
        if m < 2:
            pytest.skip("needs a real mesh")
        rng = np.random.default_rng(11)
        rows = 1 << 10
        idx = jnp.asarray(rng.integers(0, rows, size=2048).astype(np.int32))
        table = jnp.arange(float(rows))
        eng = ShardedEngine(mesh=m)
        out_b = eng.sharded_gather(table, idx, placement="block")
        lf_block = eng.last_shard_stats.local_fraction
        out_o = eng.sharded_gather(table, idx, placement="owner")
        lf_owner = eng.last_shard_stats.local_fraction
        np.testing.assert_array_equal(np.asarray(out_b), np.asarray(out_o))
        assert lf_owner >= 0.9 > lf_block

    @pytest.mark.parametrize("codec", ["raw", "bitmap", "delta"])
    def test_codec_paths_bit_exact(self, codec):
        """Compressed exchange is bit-exact vs raw at every mesh size,
        for gathers and RMWs, including OOB and duplicate-heavy lanes."""
        rng = np.random.default_rng(7)
        rows = 96
        idx = rng.integers(-8, rows + 8, size=300).astype(np.int32)
        vals = rng.integers(0, 32, size=300).astype(np.int32)
        table = jnp.asarray(rng.normal(size=(rows, 3)).astype(np.float32))
        itab = jnp.asarray(rng.integers(0, 99, size=rows).astype(np.int32))
        want_g = np.asarray(table)[np.clip(idx, 0, rows - 1)]
        want_r = np.asarray(itab).copy()
        ok = (idx >= 0) & (idx < rows)
        np.add.at(want_r, idx[ok], vals[ok])
        for m in MESH_SIZES:
            eng = ShardedEngine(mesh=m)
            out = eng.sharded_gather(table, jnp.asarray(idx), codec=codec)
            np.testing.assert_array_equal(np.asarray(out), want_g)
            new = eng.sharded_rmw(itab, jnp.asarray(idx),
                                  jnp.asarray(vals), op="ADD", codec=codec)
            np.testing.assert_array_equal(np.asarray(new), want_r)

    def test_split_route_exec_matches_fused(self):
        """gather_start/finish and rmw_start/finish (the emit stage's
        overlap path) produce exactly the fused single-dispatch result
        and record an overlap fraction."""
        m = MESH_SIZES[-1]
        rng = np.random.default_rng(13)
        rows = 128
        idx = jnp.asarray(rng.integers(0, rows, size=256).astype(np.int32))
        vals = jnp.asarray(rng.integers(0, 9, size=256).astype(np.int32))
        table = jnp.asarray(rng.normal(size=(rows, 2)).astype(np.float32))
        itab = jnp.asarray(rng.integers(0, 9, size=rows).astype(np.int32))
        eng = ShardedEngine(mesh=m)
        fused = eng.sharded_gather(table, idx)
        assert eng.last_shard_stats.overlap_fraction is None
        fl = eng.gather_start(table, idx)
        split = eng.gather_finish(table, fl)
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(split))
        assert eng.last_shard_stats.overlap_fraction in (0.0, 1.0)
        fused_r = eng.sharded_rmw(itab, idx, vals, op="ADD")
        fl = eng.rmw_start(itab, idx, vals, op="ADD")
        split_r = eng.rmw_finish(itab, fl)
        np.testing.assert_array_equal(np.asarray(fused_r),
                                      np.asarray(split_r))

    def test_rejects_non_rmw_op(self):
        eng = ShardedEngine(mesh=1)
        with pytest.raises(ValueError, match="RMW_OPS"):
            eng.sharded_rmw(jnp.arange(8), jnp.zeros(4, jnp.int32),
                            jnp.zeros(4), op="SUB")


# ---------------------------------------------------------------------------
# scheduler / serve integration
# ---------------------------------------------------------------------------

class TestSchedulerIntegration:
    @pytest.mark.parametrize("m", MESH_SIZES)
    def test_submit_gather_spans_mesh(self, m):
        sched = Scheduler(engine=ShardedEngine(mesh=m, tile_size=256))
        rng = np.random.default_rng(m)
        table = jnp.asarray(rng.normal(size=(128, 4)).astype(np.float32))
        streams = [rng.integers(0, 128, size=64).astype(np.int32)
                   for _ in range(5)]
        tickets = [sched.submit_gather(table, s, tenant=f"c{i}")
                   for i, s in enumerate(streams)]
        report = sched.flush()
        for t, s in zip(tickets, streams):
            np.testing.assert_array_equal(np.asarray(sched.result(t)),
                                          np.asarray(table)[s])
        # per-shard stats rolled into the flush report
        assert len(report.shard_stats) == 1
        (st,) = report.shard_stats.values()
        assert st.sent.shape == (m, m)
        assert (st.coalescing_gain >= 1).all()
        # the exchange carries the deduped fetch, not the coalesce padding:
        # lanes on the fabric == truly unique rows across all tenants
        n_uniq = np.unique(np.concatenate(streams)).shape[0]
        assert int(np.asarray(st.received).sum()) == n_uniq

    def test_single_device_engine_has_no_shard_stats(self):
        sched = Scheduler()
        t = sched.submit_gather(jnp.arange(16.0),
                                jnp.asarray([3, 3, 1], jnp.int32))
        report = sched.flush()
        np.testing.assert_array_equal(np.asarray(sched.result(t)),
                                      [3.0, 3.0, 1.0])
        assert report.shard_stats == {}

    @pytest.mark.parametrize("m", MESH_SIZES)
    def test_batched_program_groups_on_mesh(self, m):
        """Grouped program execution through the sharded engine's lane
        fan-out agrees with the per-program oracle (vmapped group of 8 =
        num_shards * local sub-batches)."""
        tile = 128
        cases = []
        rng = np.random.default_rng(0)
        for k in range(8):
            pat = Pattern([Access("LD", "A", Load("B", Var("i")),
                                  dtype="f32")], name=f"lane{k}")
            env = {"A": rng.normal(size=200).astype(np.float32),
                   "B": rng.integers(0, 200, size=256).astype(np.int32)}
            cases.append((pat, env, 100))
        sched = Scheduler(engine=ShardedEngine(mesh=m, tile_size=tile))
        checked, report = harness.check_scheduler_parity(
            cases, tile_size=tile, scheduler=sched)
        assert checked > 0
        assert any(g.vmapped for g in report.groups)


class TestAccessServiceMesh:
    def test_service_mesh_kwarg(self):
        svc = AccessService(mesh=MESH_SIZES[-1], tile_size=256,
                            auto_flush=0)
        assert isinstance(svc.scheduler.engine, ShardedEngine)
        core = svc.connect("c0")
        table = jnp.arange(64.0)
        t = core.submit_gather(table, jnp.asarray([5, 9, 5], jnp.int32))
        np.testing.assert_array_equal(np.asarray(core.wait(t)),
                                      [5.0, 9.0, 5.0])
        assert svc.last_report.shard_stats

    def test_mesh_plus_scheduler_rejected(self):
        with pytest.raises(ValueError, match="not both"):
            AccessService(scheduler=Scheduler(), mesh=1)
