"""dx-lint: static analysis CLI for AccessPrograms, Patterns and traces.

Three modes, combinable in one invocation:

  python tools/dx_lint.py [FILE.py ...]     lint python modules
  python tools/dx_lint.py --fuzz N          lint the N-seed fuzz corpus
  python tools/dx_lint.py --trace FILE.json lint a committed traffic trace

File mode imports each module and lints every module-global
``isa.AccessProgram`` and ``compiler.Pattern`` (compiled first) through
``repro.analysis.analyze_program``. Fuzz mode is the zero-false-positive
gate: every ``fuzzer.generate_case`` program and every
``fuzzer.generate_mixed_case`` window is legal by construction, so ANY
ERROR-level diagnostic is an analyzer bug and fails the run. Mixed
windows are lowered (never executed) through a real ``Scheduler`` so the
window hazard scan (``analysis.hazards``) runs exactly as in production.
Trace mode replays a ``serve.traffic`` JSON trace through an
``AccessService`` and reports the per-window diagnostics the telemetry
collected.

Exit codes: 0 clean (WARNs allowed, reported), 1 ERROR-level findings,
2 usage / unreadable input.
"""
from __future__ import annotations

import argparse
import importlib.util
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))


def _report(label: str, diags, counts) -> None:
    for d in diags:
        counts[d.severity] = counts.get(d.severity, 0) + 1
        print(f"{label}: {d.render()}")


def lint_file(path: Path, counts) -> int:
    """Import ``path`` and lint its module-global programs/patterns.
    Returns the number of lintable objects found."""
    from repro.analysis import analyze_program
    from repro.core import compiler, isa

    spec = importlib.util.spec_from_file_location(
        f"_dxlint_{path.stem}", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)

    found = 0
    for name, obj in sorted(vars(mod).items()):
        if isinstance(obj, compiler.Pattern):
            prog, _ = compiler.compile_pattern(obj)
        elif isinstance(obj, isa.AccessProgram):
            prog = obj
        else:
            continue
        found += 1
        analysis = analyze_program(prog, externals=frozenset())
        _report(f"{path.name}:{name}", analysis.diagnostics, counts)
    return found


def lint_fuzz(n_seeds: int, counts) -> None:
    """Zero-false-positive gate over the legal fuzz corpus: compiled
    single programs (interval analyzer) and mixed flush windows (hazard
    scan via a lowering-only Scheduler pass). DX020 float-reduction
    WARNs are expected on ~a quarter of mixed seeds; ERRORs never."""
    import numpy as np

    from repro.analysis import analyze_program
    from repro.core import Engine, Scheduler, compiler
    from repro.testing import fuzzer

    for seed in range(n_seeds):
        case = fuzzer.generate_case(seed)
        prog, _ = compiler.compile_pattern(case.pattern, tile_size=256)
        env = dict(case.env)
        env["__iota__"] = np.arange(256, dtype=np.int32)
        regs = {"tile_base": 0, "N": case.n, "tile_end": case.n}
        analysis = analyze_program(prog, env=env, regs=regs,
                                   externals=frozenset())
        _report(f"fuzz[{seed}]", analysis.diagnostics, counts)

    sched = Engine(tile_size=256)
    for seed in range(n_seeds):
        case = fuzzer.generate_mixed_case(seed)
        win = Scheduler(engine=sched, strict=False)
        for name, idx in case.gathers:
            win.submit_gather(case.tables[name], idx)
        for name, idx, vals, cond in case.rmws:
            win.submit_rmw(case.tables[name], idx, vals,
                           op=case.table_ops[name], cond=cond)
        # lower only — the hazard scan rides the lowering, no execution
        plan = win.explain().plan
        _report(f"mixed[{seed}]", plan.diagnostics, counts)


def lint_trace(path: Path, counts) -> None:
    """Replay a committed traffic trace; collect per-window hazards."""
    from repro.serve import AccessService
    from repro.serve.traffic import Trace, replay_trace

    trace = Trace.from_json(path.read_text())
    svc = AccessService(tile_size=256, auto_flush=0)
    replay_trace(trace, svc)
    svc.flush()
    diag = svc.telemetry.summary().get("diagnostics", {})
    for code, n in sorted(diag.get("by_code", {}).items()):
        from repro.analysis import CATALOG
        sev, summary = CATALOG[code]
        counts[sev] = counts.get(sev, 0) + n
        print(f"{path.name}: {code} {sev} x{n}: {summary}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="dx_lint", description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="*", type=Path,
                    help="python modules to lint")
    ap.add_argument("--fuzz", type=int, metavar="N", default=0,
                    help="lint the first N fuzz-corpus seeds "
                         "(any ERROR is a false positive -> exit 1)")
    ap.add_argument("--trace", type=Path, default=None,
                    help="replay a serve.traffic JSON trace")
    args = ap.parse_args(argv)

    if not args.files and not args.fuzz and args.trace is None:
        ap.print_usage(sys.stderr)
        return 2

    counts: dict = {}
    n_objects = 0
    for f in args.files:
        if not f.exists():
            print(f"dx_lint: no such file: {f}", file=sys.stderr)
            return 2
        n_objects += lint_file(f, counts)
    if args.files:
        print(f"linted {len(args.files)} module(s), "
              f"{n_objects} program(s)/pattern(s)")
    if args.fuzz:
        lint_fuzz(args.fuzz, counts)
        print(f"linted {args.fuzz} fuzz seeds + {args.fuzz} mixed windows")
    if args.trace is not None:
        if not args.trace.exists():
            print(f"dx_lint: no such trace: {args.trace}", file=sys.stderr)
            return 2
        lint_trace(args.trace, counts)

    errs = counts.get("ERROR", 0)
    warns = counts.get("WARN", 0)
    print(f"dx_lint: {errs} error(s), {warns} warning(s)")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
