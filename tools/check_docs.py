"""Docs consistency gate (CI `docs` job; also run by tests/test_docs.py).

Four checks, all pure-stdlib (no jax import — the docs job stays fast
and install-free):

  1. Internal markdown links in README.md, DESIGN.md and docs/*.md
     resolve: every relative ``[text](target)`` must point at a file
     that exists (anchors are stripped; http(s) links are skipped).
  2. Every app module under ``src/repro/apps/`` is mentioned in
     DESIGN.md — a new app cannot land undocumented.
  3. Every analysis module under ``src/repro/analysis/`` is mentioned
     in DESIGN.md (§12 documents the DX0xx diagnostic catalog).
  4. Committed bench snapshots (``benchmarks/snapshots/BENCH_*.json``)
     and ``benchmarks/run.py`` registrations agree both ways: a
     registered module without a committed gate snapshot is unguarded,
     a snapshot without a registration is dead weight that
     ``benchmarks.compare`` would silently never refresh.

Exit 0 when clean; exit 1 with one line per violation otherwise.

  python tools/check_docs.py [repo_root]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# benchmarks/run.py registers modules as ("name", module) pairs inside
# main(); the argparse choices tuple lists the same names
CHOICES_RE = re.compile(r"choices=\(([^)]*)\)", re.DOTALL)


def check_links(root: Path, errors: list) -> None:
    docs = [root / "README.md", root / "DESIGN.md", root / "ROADMAP.md"]
    docs += sorted((root / "docs").glob("*.md"))
    for doc in docs:
        if not doc.exists():
            errors.append(f"{doc.relative_to(root)}: file missing")
            continue
        for m in LINK_RE.finditer(doc.read_text()):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path = target.split("#", 1)[0]
            if not path:                  # pure in-page anchor
                continue
            resolved = (doc.parent / path).resolve()
            if not resolved.exists():
                errors.append(
                    f"{doc.relative_to(root)}: broken link -> {target}")


def check_apps_documented(root: Path, errors: list) -> None:
    design = (root / "DESIGN.md").read_text()
    apps_dir = root / "src" / "repro" / "apps"
    for mod in sorted(apps_dir.glob("*.py")):
        name = mod.stem
        if name == "__init__":
            continue
        if name not in design:
            errors.append(
                f"DESIGN.md: app module src/repro/apps/{name}.py "
                f"is not mentioned")


def check_analysis_documented(root: Path, errors: list) -> None:
    """Every static-analysis module must be covered by DESIGN.md §12 —
    the diagnostic catalog is a documented contract, not an
    implementation detail."""
    design = (root / "DESIGN.md").read_text()
    ana_dir = root / "src" / "repro" / "analysis"
    for mod in sorted(ana_dir.glob("*.py")):
        name = mod.stem
        if name == "__init__":
            continue
        if name not in design:
            errors.append(
                f"DESIGN.md: analysis module src/repro/analysis/{name}.py "
                f"is not mentioned")


def check_bench_snapshots(root: Path, errors: list) -> None:
    run_src = (root / "benchmarks" / "run.py").read_text()
    m = CHOICES_RE.search(run_src)
    if not m:
        errors.append("benchmarks/run.py: cannot find argparse choices")
        return
    registered = set(re.findall(r'"([a-z_]+)"', m.group(1)))
    snaps = {p.stem.removeprefix("BENCH_")
             for p in (root / "benchmarks" / "snapshots").glob("BENCH_*.json")}
    for name in sorted(registered - snaps):
        # locality/tilesize-style sweeps carry no gate rows — only flag
        # modules that emit gate_ratio rows (grep their source)
        mod_path = root / "benchmarks" / f"{name}.py"
        alt = root / "benchmarks" / f"{name}_bench.py"
        src = (mod_path.read_text() if mod_path.exists() else
               alt.read_text() if alt.exists() else "")
        if "gate_ratio" in src:
            errors.append(
                f"benchmarks/run.py registers '{name}' (emits gate_ratio "
                f"rows) but benchmarks/snapshots/BENCH_{name}.json is not "
                f"committed")
    for name in sorted(snaps - registered):
        errors.append(
            f"benchmarks/snapshots/BENCH_{name}.json has no matching "
            f"registration in benchmarks/run.py")


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    root = Path(argv[0]).resolve() if argv \
        else Path(__file__).resolve().parent.parent
    errors: list = []
    check_links(root, errors)
    check_apps_documented(root, errors)
    check_analysis_documented(root, errors)
    check_bench_snapshots(root, errors)
    for e in errors:
        print(f"check_docs: {e}", file=sys.stderr)
    if not errors:
        print(f"check_docs: OK ({root})")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
