"""No-network lint fallback: pyflakes under the repo's ruff ignore policy.

The CI lint job's primary path is ruff, whose binary wheel has been
uninstallable in the offline build container since PR 2. This driver
covers the F-class checks with pure-python pyflakes — but bare pyflakes
knows nothing of the repo's ruff configuration (pyproject.toml), so it
would fail a clean tree. Two rules are mirrored here:

  * ``per-file-ignores: "src/repro/**/__init__.py" = ["F401"]`` —
    package ``__init__`` files are re-export modules; "imported but
    unused" is their whole point. (Applied to every ``__init__.py``:
    the repo has no non-package inits.)
  * ``# noqa`` comments — ruff honors them, pyflakes does not. A bare
    ``# noqa`` suppresses the line; ``# noqa: <codes>`` suppresses it
    only if an F-class code is listed (pyflakes emits only the F
    family, so a line excused solely for another rule — e.g.
    ``# noqa: E501`` — still fails on a real pyflakes finding).

Usage (exit status 1 iff any message survives the filters):

    python tools/lint_fallback.py src tests benchmarks examples
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

from pyflakes import api as pyflakes_api

_NOQA = re.compile(r"#\s*noqa(?::\s*(?P<codes>[A-Z0-9, ]+))?", re.I)


class _Collector:
    """pyflakes Reporter collecting (filename, lineno, text) triples."""

    def __init__(self):
        self.messages = []

    def unexpectedError(self, filename, msg):            # noqa: N802
        self.messages.append((str(filename), 0, str(msg)))

    def syntaxError(self, filename, msg, lineno, offset, text):  # noqa: N802
        self.messages.append((str(filename), int(lineno or 0),
                              f"syntax error: {msg}"))

    def flake(self, message):
        self.messages.append(
            (str(message.filename), int(message.lineno),
             message.message % message.message_args))


def _noqa_suppresses(line: str) -> bool:
    """ruff-style noqa on the line's comment: bare ``# noqa`` always
    suppresses; ``# noqa: <codes>`` only if an F code is listed (the
    only family pyflakes emits)."""
    m = _NOQA.search(line)
    if m is None:
        return False
    codes = m.group("codes")
    if not codes:
        return True
    return any(c.strip().upper().startswith("F")
               for c in codes.split(",") if c.strip())


def _allowed(filename: str, lineno: int, text: str) -> bool:
    """True if the repo's ruff policy would suppress this message."""
    if filename.endswith("__init__.py") and "imported but unused" in text:
        return True
    if lineno > 0:
        try:
            line = Path(filename).read_text().splitlines()[lineno - 1]
        except (OSError, IndexError):
            return False
        return _noqa_suppresses(line)
    return False


def run(paths) -> int:
    files = []
    for p in paths:
        p = Path(p)
        files.extend(sorted(p.rglob("*.py")) if p.is_dir() else [p])
    collector = _Collector()
    for f in files:
        pyflakes_api.checkPath(str(f), collector)
    failures = 0
    for filename, lineno, text in collector.messages:
        if _allowed(filename, lineno, text):
            continue
        print(f"{filename}:{lineno}: {text}")
        failures += 1
    print(f"lint_fallback: {len(files)} files, {failures} finding(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(run(sys.argv[1:] or ["src", "tests", "benchmarks",
                                  "examples"]))
